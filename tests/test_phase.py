"""Phase-aware (prefill vs decode) scheduling tests: KV-cache bytes
excluded from the active peak, engine agreement with the decode
closed forms, phase_schedule crossovers at M=1, weight-reload
accounting on block switches, and the block-periodic spacegen
property (periodic results bit-identical to members of the
non-periodic enumeration)."""

import dataclasses
from types import SimpleNamespace

import pytest

from repro.core import analytical as an
from repro.core import fusion, spacegen, validation
from repro.core import scheduler as sch
from repro.core import workload as wl
from repro.core.accelerator import multi_core_array, pe_array_64x64

ACCEL = pe_array_64x64()
CFG = SimpleNamespace(name="toy", d_model=64, n_heads=2, kv_heads=1,
                      head_dim=32, d_ff=128)


def _key(res: sch.Result):
    """Everything that identifies an evaluation except the name."""
    return (res.latency_cycles, res.energy_pj, res.energy_scaled_pj,
            res.peak_active_words, tuple(res.trace))


def _score_fused(prefix: str = "") -> sch.Schedule:
    p = prefix
    return spacegen.chain_schedule(
        "fused[QKT->SM->AV]",
        [f"{p}Q", f"{p}K", f"{p}V", f"{p}QKT", f"{p}SM", f"{p}AV"],
        fused={(f"{p}QKT", f"{p}SM"), (f"{p}SM", f"{p}AV")})


# ------------------------------------------------ KV-cache accounting
def test_kv_cache_excluded_from_active_peak():
    w = wl.kv_cached_attention(1, 4096, 64)
    res = sch.evaluate(w, ACCEL, sch.layer_by_layer(w), row_block=1)
    assert w.kv_cache_words == 2 * 4096 * 64
    assert res.kv_cache_words == w.kv_cache_words
    # the cache footprint dwarfs the active peak and is NOT inside it
    assert res.peak_active_words < res.kv_cache_words
    assert res.peak_active_words == an.a_lbl_kv(1, 4096, 64)


@pytest.mark.parametrize("M", [1, 2])
@pytest.mark.parametrize("C_over_N", [1, 2, 4, 16])
def test_decode_closed_forms_match_engine(M, C_over_N):
    N = 64
    C = C_over_N * N
    head = wl.kv_cached_attention(M, C, N)
    lbl = sch.evaluate(head, ACCEL, sch.layer_by_layer(head),
                       row_block=1)
    fused = sch.evaluate(head, ACCEL, _score_fused(), row_block=1)
    assert lbl.peak_active_words == an.a_lbl_kv(M, C, N)
    assert fused.peak_active_words == an.a_lf_kv(M, C, N)
    # fusing the score pipeline never raises latency (the paper's
    # same-optimal-latency constraint holds in the cached regime too)
    assert fused.latency_cycles <= lbl.latency_cycles


# ----------------------------------------------- phase decision rule
def test_phase_schedule_agrees_with_analytical_crossover_at_M1():
    N = CFG.head_dim
    for C in (N, 2 * N, 4 * N, 64 * N):
        plan = fusion.phase_schedule(CFG, "decode", C)
        assert plan.M == 1 and plan.score_cols == C
        assert plan.alpha == an.alpha_kv(1, C, N)
        # score fusion is chosen exactly when the closed form predicts
        # a gain: alpha_kv < 1  <=>  C > 2N
        assert plan.fuse_scores == (an.alpha_kv(1, C, N) < 1.0)
        assert plan.fuse_scores == (C > 2 * N)


def test_phase_schedule_prefill_reduces_to_paper_rule():
    N = CFG.head_dim
    for M in (N // 2, N, 4 * N):
        plan = fusion.phase_schedule(CFG, "prefill", M)
        sel = fusion.select_schedule(M, N)
        assert plan.policy == sel
        assert plan.alpha == an.alpha(M, N)


@pytest.mark.parametrize("phase,seq", [("prefill", 32), ("decode", 4096)])
def test_phase_schedule_validates_and_evaluates(phase, seq):
    plan = fusion.phase_schedule(CFG, phase, seq, n_blocks=2)
    assert validation.validate_schedule(plan.workload,
                                        plan.schedule) == []
    res = sch.evaluate(plan.workload, ACCEL, plan.schedule,
                       row_block=1)
    base = sch.evaluate(plan.workload, ACCEL,
                        sch.layer_by_layer(plan.workload), row_block=1)
    assert res.peak_active_words <= base.peak_active_words
    assert res.kv_cache_words == plan.workload.kv_cache_words
    if phase == "decode":
        # seq >> 2 * head_dim: score fusion must strictly win
        assert res.peak_active_words < base.peak_active_words


# ------------------------------------------------- weight residency
def test_weight_reload_charged_on_block_switch():
    net = wl.network(CFG, 2, phase="prefill", seq_len=8)
    res = sch.evaluate(net, ACCEL, sch.layer_by_layer(net), row_block=8)
    # one core walks block 0 then block 1: exactly block 1's weights
    # are reloaded (the first-touched block is ambient, not a reload)
    assert res.weight_reload_words == net.block_weight_words(1)
    assert res.weight_reload_cycles > 0

    single = wl.network(CFG, 1, phase="prefill", seq_len=8)
    r1 = sch.evaluate(single, ACCEL, sch.layer_by_layer(single),
                      row_block=8)
    assert r1.weight_reload_words == 0


def test_block_pipelined_placement_keeps_weights_resident():
    net = wl.network(CFG, 2, phase="prefill", seq_len=16)
    cands = spacegen.generate(net, 2, spacegen.SpaceOptions(
        max_orderings=1, max_cuts=2, max_candidates=8))
    bp = [s for s in cands if s.name.endswith("@bp")]
    assert bp, [s.name for s in cands]
    res = sch.evaluate(net, multi_core_array(2), bp[0], row_block=8)
    # each core owns one block: no reloads, activations pay the link
    assert res.weight_reload_words == 0
    assert res.comm_cycles > 0


def test_single_block_results_unchanged_by_phase_fields():
    """Seed regression: a plain prefill block evaluates bit-identically
    whether built directly or as a 1-block network facade."""
    blk = wl.transformer_block(16, 64, 2, 128, n_kv_heads=1, d_head=32)
    res = sch.evaluate(blk, ACCEL, sch.layer_by_layer(blk), row_block=4)
    assert res.kv_cache_words == 0
    assert res.weight_reload_words == 0


# ------------------------------------- block-periodic space property
def _check_periodic_bit_identical(phase: str, norm: str):
    """Every schedule the block-periodic generator emits for a 2-block
    network evaluates bit-identically to a member of the full
    non-periodic enumeration (with caps large enough that neither
    path truncates)."""
    cfg = SimpleNamespace(name="t", d_model=16, n_heads=1, kv_heads=1,
                          head_dim=16, d_ff=32, mlp="gelu")
    seq, n_ctx = (4, 0) if phase == "prefill" else (1, 16)
    net = wl.network(cfg, 2, phase=phase, seq_len=seq, n_ctx=n_ctx,
                     norm=norm)
    opts = spacegen.SpaceOptions(max_orderings=400, max_cuts=12,
                                 max_candidates=100000)
    periodic = spacegen.generate(net, 1, opts)
    generic = spacegen.generate(
        net, 1, dataclasses.replace(opts, periodic=False))
    assert periodic and generic
    per_keys = {_key(sch.evaluate(net, ACCEL, s, row_block=2))
                for s in periodic}
    gen_keys = {_key(sch.evaluate(net, ACCEL, s, row_block=2))
                for s in generic}
    assert per_keys <= gen_keys


try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # degrade to parametrization
    @pytest.mark.parametrize("phase", ["prefill", "decode"])
    @pytest.mark.parametrize("norm", ["pre", "post"])
    def test_periodic_results_bit_identical_to_nonperiodic(phase, norm):
        _check_periodic_bit_identical(phase, norm)
else:
    @settings(max_examples=4, deadline=None)
    @given(phase=st.sampled_from(["prefill", "decode"]),
           norm=st.sampled_from(["pre", "post"]))
    def test_periodic_results_bit_identical_to_nonperiodic(phase, norm):
        _check_periodic_bit_identical(phase, norm)
