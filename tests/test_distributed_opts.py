"""§Perf distributed optimizations, validated on a multi-device host
mesh (this test file re-execs itself with 8 XLA host devices)."""

import os
import subprocess
import sys

import pytest

# JAX-heavy tier: deselect with -m 'not slow' for the fast core-DSE tier
pytestmark = pytest.mark.slow

SCRIPT_DECODE = r"""
import jax, jax.numpy as jnp
from repro.sharding import set_rules_for_mesh
from repro.serve.distributed_decode import distributed_decode_attention
from repro.kernels import ref
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (4, 8, 1, 32))
k = jax.random.normal(ks[1], (4, 2, 64, 32))
v = jax.random.normal(ks[2], (4, 2, 64, 32))
lengths = jnp.array([64, 17, 33, 5])
with set_rules_for_mesh(mesh):
    out = jax.jit(lambda *a: distributed_decode_attention(*a))(q, k, v, lengths)
exp = ref.attention_reference(q, k, v, causal=False, lengths=lengths)
err = float(jnp.abs(out - exp).max())
assert err < 5e-6, err
print("OK", err)
"""

SCRIPT_EP = r"""
import jax, jax.numpy as jnp, dataclasses
from repro.models import ModelConfig
from repro.models import moe as moe_mod
from repro.sharding import set_rules_for_mesh
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = ModelConfig(name="m", n_layers=1, d_model=64, n_heads=4, d_ff=0,
                  vocab_size=10, moe=True, n_experts=8, top_k=2,
                  d_expert=96, capacity_factor=8.0)
p = jax.tree.map(lambda q: q.value, moe_mod.init_moe(jax.random.PRNGKey(0), cfg),
                 is_leaf=lambda x: hasattr(x, "axes"))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 64)) * 0.5
cfg_ep = dataclasses.replace(cfg, moe_shard_map_ep=True)
with set_rules_for_mesh(mesh):
    y1, _ = jax.jit(lambda p, x: moe_mod.moe_forward(p, cfg, x))(p, x)
    y2, _ = jax.jit(lambda p, x: moe_mod.moe_forward(p, cfg_ep, x))(p, x)
    err = float(jnp.abs(y1 - y2).max())
    g1 = jax.jit(jax.grad(lambda p, x: (moe_mod.moe_forward(p, cfg, x)[0]**2).sum()))(p, x)
    g2 = jax.jit(jax.grad(lambda p, x: (moe_mod.moe_forward(p, cfg_ep, x)[0]**2).sum()))(p, x)
    gerr = max(float(jnp.abs(a-b).max())
               for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
assert err < 1e-6, err
assert gerr < 1e-3, gerr
print("OK", err, gerr)
"""


def _run_in_subprocess(script: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_distributed_decode_multidevice():
    """Partial-softmax decode combine == reference, 8 devices."""
    _run_in_subprocess(SCRIPT_DECODE)


def test_shard_map_ep_multidevice():
    """Explicit EP all-to-all dataflow == baseline MoE, fwd + grads."""
    _run_in_subprocess(SCRIPT_EP)
