"""Chaos suite: deterministic fault injection against the serving
stack.

The acceptance contract (see docs/serving.md "Fault tolerance"): under
every injected fault schedule the supervised stream completes with
token-for-token parity against the fault-free run, the state auditor
finds zero violations on every step, the per-seed incident ledger is
bit-identical run-to-run, and a crash + snapshot-restore resumes the
stream bit-identically.  The CI ``chaos`` job runs this file twice
(CHAOS_SEED=0 and 1).
"""

import dataclasses
import os

import pytest

# JAX-heavy tier: deselect with -m 'not slow' for the fast core-DSE tier
pytestmark = pytest.mark.slow

import jax
import numpy as np

from repro import configs, lower
from repro.checkpoint import CheckpointManager
from repro.models import init_params_and_axes
from repro.serve import (ContinuousBatchingEngine, FaultInjector,
                         FaultSpec, IncidentLedger,
                         PagedContinuousBatchingEngine, Request,
                         RequestBatcher, ServingSupervisor,
                         audit_engine, make_serving_plan)

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))


@pytest.fixture(scope="module")
def qwen():
    cfg = configs.get_config("qwen3-8b", smoke=True)   # N=32, 2N=64
    params, _ = init_params_and_axes(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompt(cfg, key, n):
    return [int(t) for t in jax.random.randint(
        jax.random.PRNGKey(key), (n,), 0, cfg.vocab_size)]


def _requests(cfg, n=5, budget=6):
    return [Request(uid=u, prompt=_prompt(cfg, u, 5 + 3 * u),
                    max_new_tokens=budget) for u in range(n)]


def _paged_stack(qwen, num_pages=13):
    cfg, params = qwen
    plan = make_serving_plan(cfg, 64, paged=True, page_size=8)
    eng = PagedContinuousBatchingEngine(
        params, cfg, batch_size=4, max_len=64, page_size=8,
        num_pages=num_pages, plan=plan, prefill_chunk=16)
    bat = RequestBatcher(batch_size=4, eos_id=-1, max_len=64)
    return eng, bat


def _dense_stack(qwen):
    cfg, params = qwen
    plan = make_serving_plan(cfg, 64)
    eng = ContinuousBatchingEngine(params, cfg, batch_size=4,
                                   max_len=64, plan=plan,
                                   prefill_chunk=16)
    bat = RequestBatcher(batch_size=4, eos_id=-1, max_len=64)
    return eng, bat


def _tokens(finished):
    return {r.uid: list(r.generated) for r in finished}


@pytest.fixture(scope="module")
def paged_baseline(qwen):
    """Fault-free supervised paged run: the parity reference."""
    eng, bat = _paged_stack(qwen)
    for r in _requests(qwen[0]):
        bat.submit(r)
    sup = ServingSupervisor(eng, bat, audit_every=1)
    fin = sup.serve(max_steps=60)
    assert not sup.failed and len(fin) == 5
    return _tokens(fin)


# ---------------------------------------------------------------------------
# fast tier: injector, ledger, ladder (no engine)
# ---------------------------------------------------------------------------

def test_fault_spec_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(kind="gremlin", step=0)


def test_fault_injector_seed_determinism():
    """Same seed -> identical schedule AND identical fired log when
    replayed against the same consultation pattern; different seed ->
    different schedule."""
    def replay(inj):
        for t in range(24):
            inj.begin_step(t)
            try:
                inj.on_alloc(0, 1)
            except Exception:
                pass
            try:
                inj.on_kernel("attention", "pallas")
            except Exception:
                pass
            inj.nan_slot()
            inj.preempt_storm()
        return inj.fired

    mk = lambda s: FaultInjector.from_seed(s, steps=24, slots=4,
                                           rate=0.4)
    a, b = mk(CHAOS_SEED), mk(CHAOS_SEED)
    assert [dataclasses.asdict(s) for s in a.schedule] == \
           [dataclasses.asdict(s) for s in b.schedule]
    assert a.schedule                      # rate 0.4 over 24 steps
    assert replay(a) == replay(b)
    other = mk(CHAOS_SEED + 1)
    assert [dataclasses.asdict(s) for s in a.schedule] != \
           [dataclasses.asdict(s) for s in other.schedule]


def test_fault_spec_times_budget():
    """times=1 fails once then lets the retry through; times=None is
    persistent within the step; both re-arm on a fresh begin_step."""
    inj = FaultInjector([FaultSpec("oom", step=0, times=1),
                         FaultSpec("nan", step=1, slot=2, times=None)])
    inj.begin_step(0)
    with pytest.raises(Exception):
        inj.on_alloc("k", 2)
    inj.on_alloc("k", 2)                   # budget spent: retry passes
    inj.begin_step(1)
    assert inj.nan_slot() == 2
    assert inj.nan_slot() == 2             # persistent all step
    inj.begin_step(2)
    assert inj.nan_slot() is None          # not armed off its step


def test_incident_ledger_excludes_timing():
    led = IncidentLedger()
    led.record(3, 1, "nan", "quarantine", "requeued")
    led.record(4, None, "stuck_step", "watchdog", "noted")
    assert led.counts() == {"nan": 1, "stuck_step": 1}
    assert [r["fault"] for r in led.rows()] == ["nan"]
    assert "stuck_step" not in led.to_json()
    assert "stuck_step" in led.to_json(include_timing=True)
    assert len(led) == 2


def test_rung_down_walks_full_ladder():
    """The kernel-failure recovery primitive descends the whole ladder
    megakernel -> qproj -> fused -> unfused/reference -> unfused/xla,
    records every step on the plan's downgrade ledger, and returns
    None off the bottom rung."""
    @dataclasses.dataclass(frozen=True)
    class ToyConfig:
        name: str = "toy"
        d_model: int = 128
        n_heads: int = 4
        kv_heads: int = 2
        head_dim: int = 32
        d_ff: int = 256
        mlp: str = "silu_glu"
        rope_theta: float = 1e6
        qk_norm: bool = False
        n_layers: int = 2

    plan = lower.lower(ToyConfig(), "decode", 256)
    d = lower.dispatch(plan, backend="tpu", entry="decode_block",
                       rope=True)
    assert (d.path, d.impl) == (lower.DECODE_MEGAKERNEL, "pallas")
    seen, before = [], len(plan.downgrades)
    while d is not None:
        d = lower.rung_down(d, "chaos test")
        if d is not None:
            seen.append((d.path, d.impl))
    assert seen == [(lower.QPROJ_ATTENTION, "pallas"),
                    (lower.FUSED_ATTENTION, "pallas"),
                    (lower.UNFUSED, "reference"),
                    (lower.UNFUSED, "xla")]
    new = plan.downgrades[before:]
    assert len(new) == 4
    assert all("chaos test" in dg.reason and "rung-down" in dg.reason
               for dg in new)


# ---------------------------------------------------------------------------
# engine tier: supervised chaos runs
# ---------------------------------------------------------------------------

def test_paged_chaos_all_fault_kinds_token_parity(qwen, paged_baseline):
    """One schedule exercising every fault kind against the paged
    engine: injected OOM, a persistent sick kernel, two NaN
    poisonings, and a preemption storm.  The stream must complete with
    token parity vs the fault-free run, zero audit violations on every
    step, and the kernel demotion must decay back to the planned
    path."""
    eng, bat = _paged_stack(qwen)
    for r in _requests(qwen[0]):
        bat.submit(r)
    # at smoke contexts (< crossover 2N=64) the resolved impl is the
    # unfused "reference" path — kernel faults must match it to fire
    inj = FaultInjector([
        FaultSpec("nan", step=1, slot=1),
        FaultSpec("oom", step=2, times=1),
        FaultSpec("kernel", step=3, impl="reference", times=None),
        FaultSpec("nan", step=4, slot=2),
        FaultSpec("preempt", step=5, count=2),
    ])
    sup = ServingSupervisor(eng, bat, injector=inj, cooloff=2,
                            audit_every=1)
    fin = sup.serve(max_steps=80)
    assert not sup.failed
    assert _tokens(fin) == paged_baseline
    counts = sup.ledger.counts()
    assert all(counts.get(k, 0) > 0
               for k in ("oom", "kernel", "nan", "preempt"))
    assert {f[1] for f in inj.fired} == {"oom", "kernel", "nan",
                                         "preempt"}
    # the sick kernel forced a rung-down, recorded on the plan ledger…
    assert any("kernel-failure recovery" in dg.reason
               for dg in eng.last_dispatch.plan.downgrades)
    # …and clean steps decayed the demotion back to the planned path
    assert eng.demotions == 0
    assert counts.get("cooloff", 0) > 0
    assert audit_engine(eng, bat) == []


def test_dense_chaos_token_parity(qwen):
    """The dense engine recovers through the same supervisor: NaN
    quarantine (via dense preempt/resume), a preemption storm, and a
    sick kernel all leave token parity intact."""
    reqs = lambda: _requests(qwen[0], n=4)
    eng0, bat0 = _dense_stack(qwen)
    for r in reqs():
        bat0.submit(r)
    base = _tokens(ServingSupervisor(eng0, bat0).serve(max_steps=60))

    eng, bat = _dense_stack(qwen)
    for r in reqs():
        bat.submit(r)
    inj = FaultInjector([
        FaultSpec("nan", step=2, slot=0),
        FaultSpec("kernel", step=3, impl="reference", times=1),
        FaultSpec("preempt", step=4, count=1),
    ])
    sup = ServingSupervisor(eng, bat, injector=inj)
    fin = sup.serve(max_steps=80)
    assert not sup.failed
    assert _tokens(fin) == base
    assert {f[1] for f in inj.fired} == {"nan", "kernel", "preempt"}


def test_seeded_chaos_ledger_deterministic(qwen, paged_baseline):
    """The CI gate: the same CHAOS_SEED replayed through a full
    supervised run produces a bit-identical incident ledger and fired
    log — and still lands token parity."""
    def run():
        eng, bat = _paged_stack(qwen)
        for r in _requests(qwen[0]):
            bat.submit(r)
        inj = FaultInjector.from_seed(
            CHAOS_SEED, steps=10, slots=4, rate=0.5, impl="reference")
        sup = ServingSupervisor(eng, bat, injector=inj, retry_budget=8,
                                audit_every=1)
        fin = sup.serve(max_steps=120)
        return sup, inj, fin

    sup_a, inj_a, fin_a = run()
    sup_b, inj_b, fin_b = run()
    assert inj_a.fired == inj_b.fired
    assert sup_a.ledger.to_json() == sup_b.ledger.to_json()
    assert _tokens(fin_a) == _tokens(fin_b)
    # every non-failed request keeps parity with the fault-free run
    assert not sup_a.failed
    assert _tokens(fin_a) == paged_baseline


def test_crash_snapshot_restore_bit_identical(qwen, paged_baseline,
                                              tmp_path):
    """Crash mid-stream, restore the latest whole-engine snapshot into
    a FRESH engine + batcher + supervisor, continue: the completed
    stream is token-identical to the uncrashed run."""
    eng, bat = _paged_stack(qwen)
    for r in _requests(qwen[0]):
        bat.submit(r)
    mgr = CheckpointManager(str(tmp_path), keep_last=3)
    sup = ServingSupervisor(eng, bat, ckpt=mgr, checkpoint_every=3,
                            audit_every=1)
    for _ in range(7):                     # checkpoints land at t=3, 6
        assert bat.active or eng._pending
        sup.step()
    assert mgr.latest_step() == 6
    del sup, eng, bat                      # the "crash"

    eng2, bat2 = _paged_stack(qwen)        # nothing submitted: restore
    sup2 = ServingSupervisor(eng2, bat2,   # owns the queue wholesale
                             ckpt=CheckpointManager(str(tmp_path)),
                             audit_every=1)
    sup2.restore()
    assert sup2.t == 6
    assert audit_engine(eng2, bat2) == []
    fin = sup2.serve(max_steps=80)
    assert not sup2.failed
    assert _tokens(fin) == paged_baseline


def test_audit_detects_seeded_corruption(qwen):
    """The auditor is not a rubber stamp: a healthy mid-stream engine
    audits clean, and each seeded corruption of the allocator/table
    state surfaces as a violation (and audits clean again once
    repaired)."""
    eng, bat = _paged_stack(qwen)
    for r in _requests(qwen[0], n=3):
        bat.submit(r)
    sup = ServingSupervisor(eng, bat)
    for _ in range(3):
        sup.step()
    assert audit_engine(eng, bat) == []
    live = [i for i, a in enumerate(eng.live) if a]
    assert len(live) >= 2
    a, b = live[0], live[1]

    # free/lease overlap
    page = eng.allocator.pages[a][0]
    eng.allocator._free.append(page)
    bad = audit_engine(eng, bat)
    assert any("both free and leased" in v for v in bad)
    eng.allocator._free.pop()
    assert audit_engine(eng, bat) == []

    # double-lease across keys (also breaks b's table-prefix match)
    stolen = eng.allocator.pages[b].pop()
    eng.allocator.pages[a].append(eng.allocator.pages[a][0])
    eng.allocator._free.append(stolen)
    bad = audit_engine(eng, bat)
    assert any("listed twice" in v or "double-leased" in v
               for v in bad)
    eng.allocator.pages[a].pop()
    eng.allocator.pages[b].append(eng.allocator._free.pop())
    assert audit_engine(eng, bat) == []

    # dangling lease / cache_len vs row_ctx divergence
    eng.allocator.pages["ghost"] = [eng.allocator._free.pop()]
    bad = audit_engine(eng, bat)
    assert any("dangling lease" in v for v in bad)
    eng.allocator._free.append(eng.allocator.pages.pop("ghost")[0])
    eng.row_ctx[a] += 1
    bad = audit_engine(eng, bat)
    assert any("row_ctx" in v for v in bad)
    eng.row_ctx[a] -= 1
    assert audit_engine(eng, bat) == []


def test_nan_retry_budget_exhaustion_fails_visibly(qwen,
                                                   paged_baseline):
    """A slot poisoned past its retry budget FAILS the request —
    ledger row, ``failed`` flag, supervisor.failed — never a silent
    drop; the rest of the batch completes with parity."""
    eng, bat = _paged_stack(qwen)
    for r in _requests(qwen[0], n=4):
        bat.submit(r)
    # slot 0 is poisoned on every early step; its request requeues to
    # the queue front and re-admits into slot 0 (lowest free slot), so
    # the same uid burns its whole budget
    inj = FaultInjector([FaultSpec("nan", step=t, slot=0)
                         for t in range(1, 6)])
    sup = ServingSupervisor(eng, bat, injector=inj, retry_budget=1,
                            audit_every=1)
    fin = sup.serve(max_steps=80)
    assert [r.uid for r in sup.failed] == [0]
    assert sup.failed[0].failed and sup.failed[0].done
    assert any(i.outcome == "failed (retry budget exhausted)"
               for i in sup.ledger.incidents)
    got = _tokens(fin)
    assert set(got) == {1, 2, 3}
    assert all(got[u] == paged_baseline[u] for u in got)
    assert audit_engine(eng, bat) == []
