"""SSD chunked-scan kernel vs the sequential-scan oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# JAX-heavy tier: deselect with -m 'not slow' for the fast core-DSE tier
pytestmark = pytest.mark.slow

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref
from repro.kernels.ssd_scan import ssd_scan


def _inputs(B, L, H, P, G, S, key=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(key), 6)
    x = jax.random.normal(ks[0], (B, L, H, P), dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (B, L, H))) * 0.1) \
        .astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    b = (jax.random.normal(ks[3], (B, L, G, S)) * 0.3).astype(dtype)
    c = (jax.random.normal(ks[4], (B, L, G, S)) * 0.3).astype(dtype)
    d = jax.random.normal(ks[5], (H,))
    return x, dt, a, b, c, d


SWEEP = [
    # B, L, H, P, G, S, chunk
    (1, 64, 1, 64, 1, 64, 32),
    (2, 256, 4, 64, 2, 128, 64),
    (1, 128, 8, 32, 4, 64, 128),   # single chunk
    (2, 96, 2, 64, 1, 32, 32),    # L % chunk == 0
]


@pytest.mark.parametrize("B,L,H,P,G,S,chunk", SWEEP)
def test_ssd_kernel_matches_ref(B, L, H, P, G, S, chunk):
    x, dt, a, b, c, d = _inputs(B, L, H, P, G, S)
    y, h = ssd_scan(x, dt, a, b, c, d, chunk=chunk, interpret=True,
                    return_final_state=True)
    yr, hr = ref.ssd_reference(x, dt, a, b, c, d, return_final_state=True)
    np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h, hr, rtol=1e-4, atol=1e-4)


def test_ssd_bf16():
    x, dt, a, b, c, d = _inputs(1, 128, 2, 64, 1, 64,
                                dtype=jnp.bfloat16)
    y = ssd_scan(x, dt, a, b, c, d, chunk=64, interpret=True)
    yr = ref.ssd_reference(x, dt, a, b, c, d)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=5e-2, atol=5e-2)


@settings(max_examples=8, deadline=None)
@given(L=st.sampled_from([32, 96, 160]),
       chunk=st.sampled_from([16, 32, 64]),
       H=st.sampled_from([1, 2, 4]))
def test_ssd_xla_chunk_invariance(L, chunk, H):
    """Output must be independent of the chunk size (pure schedule)."""
    x, dt, a, b, c, d = _inputs(1, L, H, 32, 1, 32, key=L + chunk)
    y1 = ops.ssd(x, dt, a, b, c, d, chunk=chunk, impl="xla")
    y2 = ops.ssd(x, dt, a, b, c, d, impl="reference")
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)


def test_ssd_grads_xla_vs_ref():
    x, dt, a, b, c, d = _inputs(1, 64, 2, 32, 1, 32)
    g1 = jax.grad(lambda x: (ops.ssd(x, dt, a, b, c, d, chunk=32,
                                     impl="xla") ** 2).sum())(x)
    g2 = jax.grad(lambda x: (ops.ssd(x, dt, a, b, c, d,
                                     impl="reference") ** 2).sum())(x)
    np.testing.assert_allclose(g1, g2, rtol=1e-3, atol=1e-3)


def test_ssd_state_continuation():
    """Chunked-prefill contract: scan(L) == scan(L/2) + scan(L/2, h0)."""
    x, dt, a, b, c, d = _inputs(1, 128, 2, 32, 1, 32)
    y_full, h_full = ops.ssd(x, dt, a, b, c, d, chunk=32, impl="xla",
                             return_final_state=True)
    y1, h1 = ops.ssd(x[:, :64], dt[:, :64], a, b[:, :64], c[:, :64], d,
                     chunk=32, impl="xla", return_final_state=True)
    y2, h2 = ops.ssd(x[:, 64:], dt[:, 64:], a, b[:, 64:], c[:, 64:], d,
                     chunk=32, impl="xla", h0=h1,
                     return_final_state=True)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h2, h_full, rtol=1e-4, atol=1e-4)


def test_ssd_step_chain_matches_scan():
    """Decode contract: T single-token steps == one scan of length T."""
    B, L, H, P, G, S = 2, 8, 2, 16, 1, 16
    x, dt, a, b, c, d = _inputs(B, L, H, P, G, S)
    y_ref = ref.ssd_reference(x, dt, a, b, c, d)
    h = jnp.zeros((B, H, P, S))
    outs = []
    for t in range(L):
        y, h = ops.ssd_step(x[:, t], dt[:, t], a, b[:, t], c[:, t], d, h)
        outs.append(y)
    np.testing.assert_allclose(jnp.stack(outs, 1), y_ref,
                               rtol=1e-4, atol=1e-4)
