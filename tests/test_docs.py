"""Docs subsystem checks, kept in the required fast tier: the public
API's docstring examples run under doctest, every relative link in
README.md and docs/ resolves, and the paper-to-code table covers every
core/ module."""

import doctest
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

from repro.core import analytical, fusion, scheduler, spacegen, workload
from repro.lower import cache as lower_cache

#: Modules whose ``>>>`` examples are part of the documented API
#: (mirrors the `docs` CI job's ``python -m doctest`` invocation).
DOCTEST_MODULES = (workload, spacegen, fusion, scheduler, analytical,
                   lower_cache)


def test_docstring_examples_run():
    for mod in DOCTEST_MODULES:
        failures, _ = doctest.testmod(mod, verbose=False)
        assert failures == 0, f"doctest failures in {mod.__name__}"


def test_markdown_links_resolve():
    sys.path.insert(0, str(REPO / "tools"))
    try:
        import check_links
    finally:
        sys.path.pop(0)
    problems = check_links.check_files(
        [REPO / "README.md", REPO / "docs"], REPO)
    assert problems == []


def test_architecture_table_covers_every_core_module():
    """docs/architecture.md's paper-to-code tables must reference every
    module of src/repro/core/ (acceptance criterion)."""
    text = (REPO / "docs" / "architecture.md").read_text()
    core = REPO / "src" / "repro" / "core"
    missing = [p.name for p in sorted(core.glob("*.py"))
               if p.name != "__init__.py" and p.name not in text]
    assert missing == []


def test_readme_names_the_three_entry_points():
    text = (REPO / "README.md").read_text()
    for needle in ("fusion.explore", "phase_schedule",
                   "select_schedule", "docs/architecture.md",
                   "pip install -e .[test]"):
        assert needle in text, f"README.md must mention {needle}"


def test_doc_snippets_match_source_verbatim():
    """Annotated code fences in docs/ (e.g. docs/serving.md's
    continuous-batching quickstart) must be verbatim contiguous regions
    of the source file they name (mirrors the docs CI job's
    ``python tools/check_snippets.py docs``)."""
    sys.path.insert(0, str(REPO / "tools"))
    try:
        import check_snippets
    finally:
        sys.path.pop(0)
    problems = check_snippets.check_files([REPO / "docs"], REPO)
    assert problems == []
    # the checker itself must catch drift (guards against a regex
    # change silently matching nothing)
    assert not check_snippets.snippet_in_file(
        "this line is nowhere in quickstart\n",
        (REPO / "examples" / "quickstart.py").read_text())


def test_bench_diff_reports_polarity_aware_deltas():
    """tools/bench_diff.py: rows matched by name, per-field deltas, and
    throughput (tokens_s) counted as better-up while wall-clock (_ms)
    counts as better-down."""
    sys.path.insert(0, str(REPO / "tools"))
    try:
        import bench_diff
    finally:
        sys.path.pop(0)
    base = {"rows": [{"name": "r", "step_ms": 10.0, "tokens_s": 4.0},
                     {"name": "gone", "x": 1}]}
    cur = {"rows": [{"name": "r", "step_ms": 12.0, "tokens_s": 5.0},
                    {"name": "new", "x": 1}]}
    d = bench_diff.diff_artifacts(base, cur)
    assert d["added"] == ["new"] and d["removed"] == ["gone"]
    (row,) = d["rows"]
    assert row["deltas"]["step_ms"]["pct"] == 20.0
    assert row["deltas"]["tokens_s"]["delta"] == 1.0
    assert bench_diff.field_polarity("step_ms") == -1
    assert bench_diff.field_polarity("tokens_s") == 1
    # the committed baseline snapshot stays diffable against itself
    snap = REPO / "benchmarks" / "baselines" / "BENCH_serving.json"
    same = json.loads(snap.read_text())
    self_diff = bench_diff.diff_artifacts(same, same)
    assert all(not r["deltas"] for r in self_diff["rows"])
    assert bench_diff.regressions(self_diff, ["tokens_s"], 0.0) == []
