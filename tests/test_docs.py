"""Docs subsystem checks, kept in the required fast tier: the public
API's docstring examples run under doctest, every relative link in
README.md and docs/ resolves, and the paper-to-code table covers every
core/ module."""

import doctest
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

from repro.core import analytical, fusion, scheduler, spacegen, workload
from repro.lower import cache as lower_cache

#: Modules whose ``>>>`` examples are part of the documented API
#: (mirrors the `docs` CI job's ``python -m doctest`` invocation).
DOCTEST_MODULES = (workload, spacegen, fusion, scheduler, analytical,
                   lower_cache)


def test_docstring_examples_run():
    for mod in DOCTEST_MODULES:
        failures, _ = doctest.testmod(mod, verbose=False)
        assert failures == 0, f"doctest failures in {mod.__name__}"


def test_markdown_links_resolve():
    sys.path.insert(0, str(REPO / "tools"))
    try:
        import check_links
    finally:
        sys.path.pop(0)
    problems = check_links.check_files(
        [REPO / "README.md", REPO / "docs"], REPO)
    assert problems == []


def test_architecture_table_covers_every_core_module():
    """docs/architecture.md's paper-to-code tables must reference every
    module of src/repro/core/ (acceptance criterion)."""
    text = (REPO / "docs" / "architecture.md").read_text()
    core = REPO / "src" / "repro" / "core"
    missing = [p.name for p in sorted(core.glob("*.py"))
               if p.name != "__init__.py" and p.name not in text]
    assert missing == []


def test_readme_names_the_three_entry_points():
    text = (REPO / "README.md").read_text()
    for needle in ("fusion.explore", "phase_schedule",
                   "select_schedule", "docs/architecture.md",
                   "pip install -e .[test]"):
        assert needle in text, f"README.md must mention {needle}"
